"""Kernel micro-benchmarks: one OR-semiring propagate round per lowering —
the pure-jnp oracle (ref), the MXU unpack-matmul (mxu), the Pallas kernel
(interpret off-TPU / real on TPU), and the packed segment reduction the
``segment`` engine backend uses.  CPU wall-time is structural; TPU numbers
come from the dry-run roofline (see ARCHITECTURE.md)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitset, engine as engine_mod
from repro.kernels import ops
from . import common

# engine backend -> frontier_step lowering it exercises on this host
_BACKEND_MODES = {
    "segment": ("segment",),
    "pallas": ("pallas",) if jax.default_backend() == "tpu"
    else ("interpret",),
}


def run(scale: str = "smoke", seed: int = 0,
        backend: str | None = None) -> list:
    rng = np.random.default_rng(seed)
    n = {"smoke": 512, "small": 2048, "full": 8192}[scale]
    a = rng.random((n, n)) < (8.0 / n)
    ap = jnp.asarray(bitset.pack_bits_np(a))
    x = jnp.asarray(rng.integers(0, 2 ** 32, size=(n, 8), dtype=np.uint32))
    # same adjacency as an edge list, for the segment-backend round
    src, dst = np.nonzero(a)
    srcj, dstj = jnp.asarray(src), jnp.asarray(dst)

    modes = (_BACKEND_MODES[engine_mod.resolve_backend(backend)]
             if backend else ("ref", "mxu", "interpret", "segment"))
    rows = []
    for mode in modes:
        if mode == "segment":
            def call():
                return np.asarray(bitset.segment_or_words(
                    x[dstj], srcj, num_segments=n, chunk_words=2))
        else:
            def call(mode=mode):
                return np.asarray(ops.frontier_step(ap, x, mode=mode))
        (_, sec) = common.time_call(call, repeat=5)
        rows.append((f"kernels/frontier_step/{mode}/V{n}",
                     round(sec * 1e6, 1), "per_round"))
    # one fully-occupied default tile (128 rows x 128 cols x 128 words):
    # the shape the vectorized kernel inner loop is specified against
    tm = tk_ = 128
    at = rng.random((tm, tk_)) < 0.05
    apt = jnp.asarray(bitset.pack_bits_np(at))
    xt = jnp.asarray(rng.integers(0, 2 ** 32, size=(tk_, 128),
                                  dtype=np.uint32))
    for mode in modes:
        if mode == "segment":
            continue   # edge-list reduction has no dense-tile analogue
        def call(mode=mode):
            return np.asarray(ops.frontier_step(apt, xt, mode=mode))
        (_, sec) = common.time_call(call, repeat=5)
        rows.append((f"kernels/frontier_step/{mode}/tile128",
                     round(sec * 1e6, 1), "per_round"))
    return rows

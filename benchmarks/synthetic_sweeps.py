"""Paper Figs 4/5: index time/space + query time vs avg degree D and |ζ|
on ER- and PA-graphs."""
from __future__ import annotations

import time

import numpy as np

from repro.core import graph as G, tdr_build
from . import common


def run(scale: str = "smoke", seed: int = 0) -> list:
    sc = common.SCALES[scale]
    rows = []
    for kind in ("er", "pa"):
        for d in sc["d"]:
            for nl in sc["labels"]:
                g = G.random_graph(kind, sc["v"], float(d), nl, seed=seed)
                t0 = time.perf_counter()
                idx = tdr_build.build_index(g, tdr_build.TDRConfig())
                bt = time.perf_counter() - t0
                sets = common.make_query_sets(
                    g, max(10, sc["queries"] // 4), 4, seed=seed)
                qtimes = {}
                for fam in ("AND", "OR", "NOT"):
                    qs_t = sets[f"{fam}-true"]
                    qs_f = sets[f"{fam}-false"]
                    qq = qs_t.queries + qs_f.queries
                    if not qq:
                        continue
                    t, _ = common.time_tdr(
                        idx, common.QuerySet("x", qq,
                                             qs_t.truth + qs_f.truth))
                    qtimes[fam] = t / len(qq) * 1e6
                rows.append((f"fig45/{kind}/D{d}/L{nl}",
                             round(bt * 1e6, 1),
                             f"index_bytes={idx.size_bytes()};"
                             + ";".join(f"{k}_us={v:.1f}"
                                        for k, v in qtimes.items())))
    return rows

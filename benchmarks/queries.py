"""Paper Table III: AND-/OR-/NOT-query time, TDR vs DFS, true & false sets.

``backend`` sweeps the packed-word engine ("segment" / "pallas"); the
harness (``run.py --backends``) records one row set per backend so the
perf trajectory of the engine refactor is tracked in BENCH_queries.json.
"""
from __future__ import annotations

import numpy as np

from repro.core import graph as G, tdr_build, tdr_query
from . import common


def run(scale: str = "smoke", seed: int = 0,
        backend: str | None = None) -> list:
    sc = common.SCALES[scale]
    rows = []
    for kind in ("er", "pa"):
        g = G.random_graph(kind, sc["v"], 4.0, 8, seed=seed)
        idx = tdr_build.build_index(g, tdr_build.TDRConfig(),
                                    backend=backend)
        sets = common.make_query_sets(g, sc["queries"], 2, seed=seed)
        for fam in ("AND", "OR", "NOT"):
            for tf in ("true", "false"):
                qs = sets[f"{fam}-{tf}"]
                if not qs.queries:
                    continue
                stats = tdr_query.QueryStats()
                tdr_s, ok = common.time_tdr(idx, qs, repeat=3,
                                            backend=backend, stats=stats)
                dfs_s, _ = common.time_dfs(g, qs)
                n = len(qs.queries)
                rows.append((f"tableIII/{kind}/{fam}-{tf}",
                             round(tdr_s / n * 1e6, 1),
                             f"dfs_us={dfs_s / n * 1e6:.1f};"
                             f"speedup={dfs_s / max(tdr_s, 1e-9):.1f}x;"
                             f"correct={ok}",
                             {"rounds": stats.exact_rounds,
                              "corridor_occ": round(
                                  stats.corridor_occupancy, 3),
                              "phase1_us": round(
                                  stats.phase1_s / n * 1e6, 1),
                              "phase2_us": round(
                                  stats.phase2_s / n * 1e6, 1)}))
    return rows

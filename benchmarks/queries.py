"""Paper Table III: AND-/OR-/NOT-query time, TDR vs DFS, true & false sets.

``backend`` sweeps the packed-word engine ("segment" / "pallas"); the
harness (``run.py --backends``) records one row set per backend so the
perf trajectory of the engine refactor is tracked in BENCH_queries.json.

The semiring rows (``dist-true`` / ``witness-true``) time the
(min,+)-carrier executors over the same reachable query sets, against
the product-graph BFS oracle (``dfs_baseline.shortest_pcr``) — the
pallas-interpret legs carry ``gated: false`` like every other
kernel-dispatch-dominated interpret row.

The ``rpq-true`` rows time the regex front-end (``tdr_query.rpq_batch``:
lowered + automaton-product routes mixed, as live traffic would be)
over oracle-reachable regex queries, normalized against the
product-graph DFS oracle (``dfs_baseline.answer_rpq``).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import dfs_baseline, engine as engine_mod
from repro.core import graph as G, rpq, tdr_build, tdr_query
from . import common


def _interpret(backend: str | None) -> bool:
    return (engine_mod.resolve_backend(backend or "auto") == "pallas"
            and jax.default_backend() != "tpu")


def run(scale: str = "smoke", seed: int = 0,
        backend: str | None = None) -> list:
    sc = common.SCALES[scale]
    rows = []
    for kind in ("er", "pa"):
        g = G.random_graph(kind, sc["v"], 4.0, 8, seed=seed)
        idx = tdr_build.build_index(g, tdr_build.TDRConfig(),
                                    backend=backend)
        sets = common.make_query_sets(g, sc["queries"], 2, seed=seed)
        for fam in ("AND", "OR", "NOT"):
            for tf in ("true", "false"):
                qs = sets[f"{fam}-{tf}"]
                if not qs.queries:
                    continue
                stats = tdr_query.QueryStats()
                tdr_s, ok = common.time_tdr(idx, qs, repeat=3,
                                            backend=backend, stats=stats)
                dfs_s, _ = common.time_dfs(g, qs)
                n = len(qs.queries)
                rows.append((f"tableIII/{kind}/{fam}-{tf}",
                             round(tdr_s / n * 1e6, 1),
                             f"dfs_us={dfs_s / n * 1e6:.1f};"
                             f"speedup={dfs_s / max(tdr_s, 1e-9):.1f}x;"
                             f"correct={ok}",
                             {"rounds": stats.exact_rounds,
                              "corridor_occ": round(
                                  stats.corridor_occupancy, 3),
                              "phase1_us": round(
                                  stats.phase1_s / n * 1e6, 1),
                              "phase2_us": round(
                                  stats.phase2_s / n * 1e6, 1)}))
        rows.extend(_semiring_rows(g, idx, kind, sets, backend))
        rows.extend(_rpq_rows(g, idx, kind, backend, seed))
    return rows


def _semiring_rows(g, idx, kind: str, sets: dict,
                   backend: str | None) -> list:
    """tableIII-style rows for the (min,+) executors: batch shortest
    distances and per-query verified witnesses over the reachable query
    sets, DFS-oracle-timed and correctness-checked like the boolean rows."""
    flag = {"gated": False} if _interpret(backend) else {}
    dist_q = (sets["AND-true"].queries + sets["OR-true"].queries
              + sets["NOT-true"].queries)
    if not dist_q:
        return []
    rows = []

    t0 = time.perf_counter()
    want = [dfs_baseline.shortest_pcr(g, u, v, p) for (u, v, p) in dist_q]
    dfs_s = time.perf_counter() - t0
    best = float("inf")
    got = None
    for _ in range(3):   # first pass warms the jit bucket grid
        t0 = time.perf_counter()
        got = tdr_query.dist_batch(idx, dist_q, backend=backend)
        best = min(best, time.perf_counter() - t0)
    n = len(dist_q)
    rows.append((f"tableIII/{kind}/dist-true",
                 round(best / n * 1e6, 1),
                 f"dfs_us={dfs_s / n * 1e6:.1f};"
                 f"speedup={dfs_s / max(best, 1e-9):.1f}x;"
                 f"correct={got.tolist() == want}",
                 dict(flag)))

    wit_q = dist_q[:6]
    wit_want = want[:6]
    ok = True
    best = float("inf")
    for rep in range(2):   # first pass warms per-bucket parent DPs
        t0 = time.perf_counter()
        for (u, v, p), d in zip(wit_q, wit_want):
            path = tdr_query.witness(idx, u, v, p, backend=backend)
            ok = ok and len(path) == d
            ok = ok and dfs_baseline.verify_witness(g, u, v, p, path)
        best = min(best, time.perf_counter() - t0)
    t0 = time.perf_counter()
    for (u, v, p) in wit_q:
        dfs_baseline.shortest_pcr(g, u, v, p)
    wdfs_s = time.perf_counter() - t0
    n = len(wit_q)
    rows.append((f"tableIII/{kind}/witness-true",
                 round(best / n * 1e6, 1),
                 f"dfs_us={wdfs_s / n * 1e6:.1f};"
                 f"speedup={wdfs_s / max(best, 1e-9):.1f}x;"
                 f"correct={ok}",
                 dict(flag)))
    return rows


def _rpq_rows(g, idx, kind: str, backend: str | None, seed: int) -> list:
    """tableIII-style row for the regex front-end: a reachable (oracle-
    true) mix of lowered and product-route regexes through
    ``rpq_batch``, DFS-normalized like the boolean rows."""
    flag = {"gated": False} if _interpret(backend) else {}
    rng = np.random.default_rng(seed + 5)
    n_l = g.n_labels

    def draw():
        a, b, c = rng.choice(n_l, size=3, replace=False).tolist()
        i = int(rng.integers(4))
        if i == 0:                                    # lowered: LCR plan
            return rpq.parse(f"(l{a} | l{b})*")
        if i == 1:                                    # product: ordered
            return rpq.parse(f"l{a} . (l{b} | l{c})*")
        if i == 2:                                    # product: Plus
            return rpq.parse(f"(l{a} | l{b} | l{c})+")
        return rpq.parse(f"l{a} . l{b}")              # product: 2-step

    qs, tries = [], 0
    while len(qs) < 96 and tries < 16000:
        tries += 1
        u = int(rng.integers(g.n_vertices))
        v = int(rng.integers(g.n_vertices))
        r = draw()
        if dfs_baseline.answer_rpq(g, u, v, r):
            qs.append((u, v, r))
    if not qs:
        return []

    t0 = time.perf_counter()
    want = [dfs_baseline.answer_rpq(g, u, v, r) for u, v, r in qs]
    dfs_s = time.perf_counter() - t0
    best = float("inf")
    got = None
    for _ in range(3):   # first pass compiles the NFA-product shapes
        t0 = time.perf_counter()
        got = tdr_query.rpq_batch(idx, qs, backend=backend)
        best = min(best, time.perf_counter() - t0)
    n = len(qs)
    return [(f"tableIII/{kind}/rpq-true",
             round(best / n * 1e6, 1),
             f"dfs_us={dfs_s / n * 1e6:.1f};"
             f"speedup={dfs_s / max(best, 1e-9):.1f}x;"
             f"correct={got.tolist() == want}",
             dict(flag))]

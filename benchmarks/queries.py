"""Paper Table III: AND-/OR-/NOT-query time, TDR vs DFS, true & false sets.

``backend`` sweeps the packed-word engine ("segment" / "pallas"); the
harness (``run.py --backends``) records one row set per backend so the
perf trajectory of the engine refactor is tracked in BENCH_queries.json.

The semiring rows (``dist-true`` / ``witness-true``) time the
(min,+)-carrier executors over the same reachable query sets, against
the product-graph BFS oracle (``dfs_baseline.shortest_pcr``) — the
pallas-interpret legs carry ``gated: false`` like every other
kernel-dispatch-dominated interpret row.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import dfs_baseline, engine as engine_mod
from repro.core import graph as G, tdr_build, tdr_query
from . import common


def _interpret(backend: str | None) -> bool:
    return (engine_mod.resolve_backend(backend or "auto") == "pallas"
            and jax.default_backend() != "tpu")


def run(scale: str = "smoke", seed: int = 0,
        backend: str | None = None) -> list:
    sc = common.SCALES[scale]
    rows = []
    for kind in ("er", "pa"):
        g = G.random_graph(kind, sc["v"], 4.0, 8, seed=seed)
        idx = tdr_build.build_index(g, tdr_build.TDRConfig(),
                                    backend=backend)
        sets = common.make_query_sets(g, sc["queries"], 2, seed=seed)
        for fam in ("AND", "OR", "NOT"):
            for tf in ("true", "false"):
                qs = sets[f"{fam}-{tf}"]
                if not qs.queries:
                    continue
                stats = tdr_query.QueryStats()
                tdr_s, ok = common.time_tdr(idx, qs, repeat=3,
                                            backend=backend, stats=stats)
                dfs_s, _ = common.time_dfs(g, qs)
                n = len(qs.queries)
                rows.append((f"tableIII/{kind}/{fam}-{tf}",
                             round(tdr_s / n * 1e6, 1),
                             f"dfs_us={dfs_s / n * 1e6:.1f};"
                             f"speedup={dfs_s / max(tdr_s, 1e-9):.1f}x;"
                             f"correct={ok}",
                             {"rounds": stats.exact_rounds,
                              "corridor_occ": round(
                                  stats.corridor_occupancy, 3),
                              "phase1_us": round(
                                  stats.phase1_s / n * 1e6, 1),
                              "phase2_us": round(
                                  stats.phase2_s / n * 1e6, 1)}))
        rows.extend(_semiring_rows(g, idx, kind, sets, backend))
    return rows


def _semiring_rows(g, idx, kind: str, sets: dict,
                   backend: str | None) -> list:
    """tableIII-style rows for the (min,+) executors: batch shortest
    distances and per-query verified witnesses over the reachable query
    sets, DFS-oracle-timed and correctness-checked like the boolean rows."""
    flag = {"gated": False} if _interpret(backend) else {}
    dist_q = (sets["AND-true"].queries + sets["OR-true"].queries
              + sets["NOT-true"].queries)
    if not dist_q:
        return []
    rows = []

    t0 = time.perf_counter()
    want = [dfs_baseline.shortest_pcr(g, u, v, p) for (u, v, p) in dist_q]
    dfs_s = time.perf_counter() - t0
    best = float("inf")
    got = None
    for _ in range(3):   # first pass warms the jit bucket grid
        t0 = time.perf_counter()
        got = tdr_query.dist_batch(idx, dist_q, backend=backend)
        best = min(best, time.perf_counter() - t0)
    n = len(dist_q)
    rows.append((f"tableIII/{kind}/dist-true",
                 round(best / n * 1e6, 1),
                 f"dfs_us={dfs_s / n * 1e6:.1f};"
                 f"speedup={dfs_s / max(best, 1e-9):.1f}x;"
                 f"correct={got.tolist() == want}",
                 dict(flag)))

    wit_q = dist_q[:6]
    wit_want = want[:6]
    ok = True
    best = float("inf")
    for rep in range(2):   # first pass warms per-bucket parent DPs
        t0 = time.perf_counter()
        for (u, v, p), d in zip(wit_q, wit_want):
            path = tdr_query.witness(idx, u, v, p, backend=backend)
            ok = ok and len(path) == d
            ok = ok and dfs_baseline.verify_witness(g, u, v, p, path)
        best = min(best, time.perf_counter() - t0)
    t0 = time.perf_counter()
    for (u, v, p) in wit_q:
        dfs_baseline.shortest_pcr(g, u, v, p)
    wdfs_s = time.perf_counter() - t0
    n = len(wit_q)
    rows.append((f"tableIII/{kind}/witness-true",
                 round(best / n * 1e6, 1),
                 f"dfs_us={wdfs_s / n * 1e6:.1f};"
                 f"speedup={wdfs_s / max(best, 1e-9):.1f}x;"
                 f"correct={ok}",
                 dict(flag)))
    return rows

"""Benchmark harness entry point — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--scale smoke|small|full]``
prints ``name,us_per_call,derived`` CSV rows (paper-table mapping in
DESIGN.md §6; roofline terms come from launch/dryrun.py, not from here).
"""
from __future__ import annotations

import argparse

from . import (common, index_cost, kernels_bench, lcr_bench, queries,
               scalability, synthetic_sweeps)

MODULES = [
    ("tableIII", queries),
    ("tableIV", index_cost),
    ("tableV", lcr_bench),
    ("fig4-5", synthetic_sweeps),
    ("fig6", scalability),
    ("kernels", kernels_bench),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke",
                    choices=sorted(common.SCALES))
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        try:
            rows = mod.run(scale=args.scale)
        except Exception as e:  # noqa
            rows = [(f"{name}/ERROR", 0, repr(e)[:120])]
        for row in rows:
            print(",".join(str(x) for x in row), flush=True)


if __name__ == "__main__":
    main()

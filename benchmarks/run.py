"""Benchmark harness entry point — one module per paper table/figure,
plus the ``serving`` load-generator suite over ``repro.launch.serve``.

``PYTHONPATH=src python -m benchmarks.run [--scale smoke|small|full]``
prints ``name,us_per_call,derived`` CSV rows (paper-table mapping and the
engine layering live in ARCHITECTURE.md; roofline terms come from
launch/dryrun.py, not from here).

``--backends segment,pallas`` sweeps the packed-word engine backends for
the modules that support it (queries, kernels); ``--json PATH`` addition-
ally writes machine-readable per-row records
``{name, us_per_call, derived, backend, scale}`` — tableIII rows also
carry the executor counters ``rounds``, ``corridor_occ`` (mean |V'|/V of
the corridor-compacted expansion), and the ``phase1_us``/``phase2_us``
wall split — so the perf trajectory is tracked across PRs (see
BENCH_queries.json at the repo root; ``benchmarks.guard`` is the CI
regression gate over those rows).
"""
from __future__ import annotations

import argparse
import inspect
import json

from repro.core import engine as engine_mod

from . import (common, fleet, index_cost, kernels_bench, lcr_bench,
               queries, recovery, scalability, serving, synthetic_sweeps,
               updates)

MODULES = [
    ("tableIII", queries),
    ("tableIV", index_cost),
    ("tableV", lcr_bench),
    ("fig4-5", synthetic_sweeps),
    ("fig6", scalability),
    ("kernels", kernels_bench),
    ("serving", serving),
    ("fleet", fleet),
    ("updates", updates),
    ("recovery", recovery),
]


def collect(scale: str, only: str = "", backends: list | None = None,
            skip: str = "") -> list:
    """Run the selected modules; returns records (dicts, one per CSV row).

    ``only``/``skip`` are comma-separated lists of substrings matched
    against the module names (skip wins — e.g. the nightly full run
    excludes the multi-process ``fleet`` module, which has its own
    saturation job); ``backends`` sweeps engine backends where
    supported.
    """
    tokens = [t for t in (only or "").split(",") if t]
    skips = [t for t in (skip or "").split(",") if t]
    records = []
    for name, mod in MODULES:
        if tokens and not any(t in name for t in tokens):
            continue
        if any(t in name for t in skips):
            continue
        supports = "backend" in inspect.signature(mod.run).parameters
        sweep = (backends or [None]) if supports else [None]
        for be in sweep:
            label = be or engine_mod.resolve_backend("auto")
            try:
                kw = {"scale": scale}
                if be is not None:
                    kw["backend"] = be
                rows = mod.run(**kw)
            except Exception as e:  # noqa
                rows = [(f"{name}/ERROR", 0, repr(e)[:120])]
            for row in rows:
                rec = {
                    "name": row[0],
                    "us_per_call": row[1],
                    "derived": row[2] if len(row) > 2 else "",
                    "backend": label if supports else "n/a",
                    "scale": scale,
                }
                if len(row) > 3 and isinstance(row[3], dict):
                    # executor counters (rounds, corridor occupancy,
                    # phase-1/phase-2 split) ride along per row
                    rec.update(row[3])
                records.append(rec)
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke",
                    choices=sorted(common.SCALES))
    ap.add_argument("--only", default="",
                    help="comma-separated substrings of module names")
    ap.add_argument("--skip", default="",
                    help="comma-separated substrings of module names "
                         "to exclude (applied after --only)")
    ap.add_argument("--backends", default="",
                    help="comma-separated engine backends to sweep "
                         "(e.g. segment,pallas); default: engine default")
    ap.add_argument("--json", default="",
                    help="also write per-row JSON records to this path")
    args = ap.parse_args()

    backends = [b for b in args.backends.split(",") if b] or None
    records = collect(args.scale, args.only, backends, skip=args.skip)

    print("name,us_per_call,backend,derived")
    for r in records:
        print(f"{r['name']},{r['us_per_call']},{r['backend']},{r['derived']}",
              flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}")


if __name__ == "__main__":
    main()

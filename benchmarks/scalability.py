"""Paper Fig 6 / Appendix C: scalability — vary |V| at fixed D, |ζ|."""
from __future__ import annotations

import time

import numpy as np

from repro.core import graph as G, tdr_build
from . import common


def run(scale: str = "smoke", seed: int = 0) -> list:
    sc = common.SCALES[scale]
    rows = []
    for kind in ("er", "pa"):
        for v in sc["scal_v"]:
            g = G.random_graph(kind, v, 6.0, min(32, 8), seed=seed)
            t0 = time.perf_counter()
            idx = tdr_build.build_index(g, tdr_build.TDRConfig())
            bt = time.perf_counter() - t0
            sets = common.make_query_sets(g, max(10, sc["queries"] // 10),
                                          4, seed=seed)
            qq = sets["AND-true"].queries + sets["NOT-false"].queries
            truth = sets["AND-true"].truth + sets["NOT-false"].truth
            qt = 0.0
            if qq:
                qt, _ = common.time_tdr(idx, common.QuerySet("x", qq, truth))
                qt = qt / len(qq) * 1e6
            rows.append((f"fig6/{kind}/V{v}", round(bt * 1e6, 1),
                         f"index_bytes={idx.size_bytes()};query_us={qt:.1f}"))
    return rows

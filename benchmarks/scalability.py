"""Paper Fig 6 / Appendix C: scalability — vary |V| at fixed D, |ζ|.

Also times the vertex-sharded distributed build/query against the
single-device path on a mesh of every local device (1 on a laptop CPU;
run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for a
real multi-device row) and records whether the planes stayed
bit-identical.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import graph as G, tdr_build
from . import common


def run(scale: str = "smoke", seed: int = 0) -> list:
    sc = common.SCALES[scale]
    rows = []
    for kind in ("er", "pa"):
        for v in sc["scal_v"]:
            g = G.random_graph(kind, v, 6.0, min(32, 8), seed=seed)
            t0 = time.perf_counter()
            idx = tdr_build.build_index(g, tdr_build.TDRConfig())
            bt = time.perf_counter() - t0
            sets = common.make_query_sets(g, max(10, sc["queries"] // 10),
                                          4, seed=seed)
            qq = sets["AND-true"].queries + sets["NOT-false"].queries
            truth = sets["AND-true"].truth + sets["NOT-false"].truth
            qt = 0.0
            if qq:
                qt, _ = common.time_tdr(idx, common.QuerySet("x", qq, truth))
                qt = qt / len(qq) * 1e6
            rows.append((f"fig6/{kind}/V{v}", round(bt * 1e6, 1),
                         f"index_bytes={idx.size_bytes()};query_us={qt:.1f}"))
    rows += _distributed_rows(scale, seed)
    return rows


def _distributed_rows(scale: str, seed: int) -> list:
    """Sharded-vs-single build on a mesh of all local devices."""
    import jax
    from jax.sharding import Mesh
    from repro.core import distributed

    sc = common.SCALES[scale]
    v = sc["scal_v"][0]
    g = G.random_graph("er", v, 4.0, 8, seed=seed)
    cfg = tdr_build.TDRConfig()
    t0 = time.perf_counter()
    idx1 = tdr_build.build_index(g, cfg)
    t_single = time.perf_counter() - t0
    devs = np.array(jax.devices())
    mesh = Mesh(devs.reshape(-1), ("data",))
    t0 = time.perf_counter()
    idxd = distributed.build_index(g, cfg, mesh=mesh)
    t_mesh = time.perf_counter() - t0
    identical = all(
        np.array_equal(np.asarray(getattr(idxd, f)),
                       np.asarray(getattr(idx1, f)))
        for f in ("h_vtx", "h_lab", "v_vtx", "v_lab", "n_out", "n_in"))
    qs = common.make_query_sets(g, max(10, sc["queries"] // 10), 2,
                                seed=seed)["AND-true"]
    t0 = time.perf_counter()
    got = distributed.answer_batch(idxd, qs.queries, mesh=mesh)
    qt = ((time.perf_counter() - t0) / max(len(qs.queries), 1)) * 1e6
    correct = got.tolist() == qs.truth
    return [(f"fig6/dist/V{v}/d{devs.size}", round(t_mesh * 1e6, 1),
             f"single_us={t_single * 1e6:.1f};bit_identical={identical};"
             f"query_us={qt:.1f};query_correct={correct}")]

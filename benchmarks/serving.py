"""Query-serving benchmark: load generators over ``repro.launch.serve``.

Measures the micro-batching scheduler the way a serving system is graded
(FERRARI-style sustained workloads, not offline batches):

* **serial-1 baseline** — the same requests issued as size-1
  ``answer_batch`` calls (steady state: plan cache warm, jit warm).  This
  is what a naive per-request front-end would get.
* **closed loop** — N concurrent clients, each submitting its next query
  when the previous answer lands; reports sustained q/s and per-request
  p50/p95/p99 latency.
* **open loop** — Poisson arrivals at a fixed offered rate through the
  non-blocking (admission-controlled) submit path; reports completed q/s,
  latency percentiles, and the shed-request count.

The module *asserts* the serving contract (raising turns the row into an
``ERROR`` row, which ``benchmarks.guard`` fails):

* closed-loop throughput >= 5x the serial-1 baseline (real-kernel paths;
  the interpret-mode pallas leg reports but does not hard-gate the
  ratio — see the MIN_SPEEDUP note below),
* zero jit recompiles across the measurement window
  (``engine.jit_cache_entries`` delta after ``QueryServer.warmup``),
* answers bit-equal to the DFS oracle.

Rows carry ``dfs_us`` so the guard's machine-drift normalization works on
the serving rows exactly as on tableIII rows.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import dfs_baseline, engine as engine_mod, graph as G
from repro.core import tdr_build, tdr_query
from repro.launch import serve

from . import common

CLIENTS = 32            # closed-loop concurrency
REQUESTS_PER_CLIENT = 20
OPEN_LOAD = 0.7         # open-loop offered rate as a fraction of closed q/s
OPEN_WINDOW_S = 2.0
MIN_SPEEDUP = 5.0       # acceptance floor vs the serial-1 baseline
# pallas-on-CPU runs the kernels in interpret mode, where per-round
# *compute* (C+1 emulated matmuls per direction, C fixed by the pinned
# label-class set) dwarfs the per-call dispatch that batching amortizes —
# a serial-1 call only scans its own query's 2-3 classes — and wall-clock
# is noise-dominated on shared hosts.  The 5x floor is the contract for
# the real-kernel paths (segment everywhere, pallas on TPU); the
# interpret leg reports its ratio but is perf-gated only through the
# guard's drift-normalized p95 comparison (correctness and the
# zero-recompile assert still apply unconditionally).


def _percentiles(lat_s: list) -> dict:
    arr = np.asarray(lat_s, dtype=np.float64) * 1e6
    if arr.size == 0:
        return {"p50_us": float("nan"), "p95_us": float("nan"),
                "p99_us": float("nan")}
    return {"p50_us": round(float(np.percentile(arr, 50)), 1),
            "p95_us": round(float(np.percentile(arr, 95)), 1),
            "p99_us": round(float(np.percentile(arr, 99)), 1)}


def _pool(g, n_per_set: int, seed: int):
    """Mixed AND/OR/NOT/LCR pool with DFS-oracle truth, interleaved so
    any contiguous batch window mixes families."""
    sets = common.make_query_sets(g, n_per_set, 2, seed=seed)
    pool, truth = [], []
    by_set = [list(zip(s.queries, s.truth)) for s in sets.values()]
    for i in range(max(len(b) for b in by_set)):
        for b in by_set:
            if i < len(b):
                q, t = b[i]
                pool.append(q)
                truth.append(t)
    return pool, truth


def _closed_loop(server, pool, truth, rng):
    """N clients, each replaying a shard of the shuffled pool."""
    order = rng.permutation(
        np.tile(np.arange(len(pool)), REQUESTS_PER_CLIENT * CLIENTS
                // len(pool) + 1))[:REQUESTS_PER_CLIENT * CLIENTS]
    shards = np.array_split(order, CLIENTS)
    lat, wrong = [], []
    lock = threading.Lock()

    def client(ids):
        for i in ids:
            u, v, p = pool[int(i)]
            t0 = time.perf_counter()
            got = server.submit(u, v, p).result()
            dt = time.perf_counter() - t0
            with lock:
                lat.append(dt)
                if got != truth[int(i)]:
                    wrong.append(int(i))

    threads = [threading.Thread(target=client, args=(s,)) for s in shards]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return len(order) / wall, lat, wrong


def _open_loop(server, pool, truth, rate_qps: float, rng):
    """Poisson arrivals at ``rate_qps`` through non-blocking submits."""
    n = max(1, int(rate_qps * OPEN_WINDOW_S))
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n))
    ids = rng.integers(0, len(pool), size=n)
    done: list = []
    wrong: list = []
    rejected = 0
    lock = threading.Lock()
    t_start = time.perf_counter()
    pending = []
    for t_arr, i in zip(arrivals, ids):
        now = time.perf_counter() - t_start
        if t_arr > now:
            time.sleep(t_arr - now)
        t0 = time.perf_counter()
        try:
            fut = server.submit(*pool[int(i)], block=False)
        except serve.QueueFull:
            rejected += 1
            continue

        def record(f, t0=t0, i=int(i)):
            dt = time.perf_counter() - t0
            with lock:
                done.append(dt)
                if f.result() != truth[i]:
                    wrong.append(i)

        fut.add_done_callback(record)
        pending.append(fut)
    for f in pending:
        f.result(timeout=120)
    wall = time.perf_counter() - t_start
    return len(done) / wall, done, wrong, rejected, n


def run(scale: str = "smoke", seed: int = 0,
        backend: str | None = None) -> list:
    sc = common.SCALES[scale]
    g = G.random_graph("er", sc["v"], 4.0, 8, seed=seed)
    idx = tdr_build.build_index(g, tdr_build.TDRConfig(), backend=backend)
    pool, truth = _pool(g, max(8, sc["queries"] // 3), seed)
    rng = np.random.default_rng(seed + 1)

    # DFS baseline (drift anchor, shared pure-python code on every host)
    t0 = time.perf_counter()
    for (u, v, p) in pool:
        dfs_baseline.answer_pcr(g, u, v, p)
    dfs_us = (time.perf_counter() - t0) / len(pool) * 1e6

    # serial-1 baseline: steady state (second pass), caches warm
    for q in pool:
        tdr_query.answer_batch(idx, [q], backend=backend)
    t0 = time.perf_counter()
    serial_ans = [bool(tdr_query.answer_batch(idx, [q], backend=backend)[0])
                  for q in pool]
    serial_qps = len(pool) / (time.perf_counter() - t0)
    ok_serial = serial_ans == truth

    rows = []
    with serve.QueryServer(idx, backend=backend, result_cache=0) as server:
        server.warmup(pool)
        n0 = engine_mod.jit_cache_entries()
        if n0 == 0:
            # the hot path definitely compiled by now: a zero here means
            # the counter itself broke (e.g. a jax upgrade renamed the
            # private _cache_size hook) and the zero-recompile assert
            # below would pass vacuously — fail loudly instead
            raise RuntimeError(
                "engine.jit_cache_entries() == 0 after warmup; the "
                "compilation counter is broken on this jax version")

        closed_qps, closed_lat, closed_wrong = _closed_loop(
            server, pool, truth, rng)
        open_rate = max(1.0, OPEN_LOAD * closed_qps)
        open_qps, open_lat, open_wrong, rejected, offered = _open_loop(
            server, pool, truth, open_rate, rng)

        recompiles = engine_mod.jit_cache_entries() - n0
        ok = ok_serial and not closed_wrong and not open_wrong
        speedup = closed_qps / serial_qps

        import jax
        interpret = (engine_mod.resolve_backend(backend or "auto")
                     == "pallas" and jax.default_backend() != "tpu")
        cp = _percentiles(closed_lat)
        op = _percentiles(open_lat)
        st = server.stats
        rows.append((
            "serving/er/closed-p95", cp["p95_us"],
            f"dfs_us={dfs_us:.1f};qps={closed_qps:.0f};"
            f"serial1_qps={serial_qps:.0f};speedup_vs_serial1="
            f"{speedup:.1f}x;recompiles={recompiles};correct={ok}",
            {**cp, "mean_batch": round(st.mean_batch, 1),
             "plan_hit_rate": round(
                 1 - st.query_stats.plan_misses
                 / max(st.query_stats.plan_lookups, 1), 3),
             # interpret-mode pallas: kernel dispatch is Python-dominated,
             # so the row reports but the guard must not gate it
             **({"gated": False} if interpret else {})}))
        rows.append((
            "serving/er/open-p95", op["p95_us"],
            f"dfs_us={dfs_us:.1f};qps={open_qps:.0f};"
            f"offered_qps={open_rate:.0f};rejected={rejected}/{offered};"
            f"correct={not open_wrong}",
            op))
        rows.append((
            "serving/er/serial1", round(1e6 / serial_qps, 1),
            f"dfs_us={dfs_us:.1f};qps={serial_qps:.0f};"
            f"correct={ok_serial}"))

        # the serving contract is load-bearing for CI: fail loudly, not
        # with a quietly degraded row
        if recompiles:
            raise RuntimeError(
                f"serving: {recompiles} jit recompiles after warmup")
        if not ok:
            raise RuntimeError(
                f"serving: answers diverged from the DFS oracle "
                f"(serial={ok_serial}, closed={len(closed_wrong)}, "
                f"open={len(open_wrong)} wrong)")
        if not interpret and speedup < MIN_SPEEDUP:
            raise RuntimeError(
                f"serving: closed-loop {closed_qps:.0f} q/s is only "
                f"{speedup:.1f}x the serial-1 baseline "
                f"({serial_qps:.0f} q/s); need >= {MIN_SPEEDUP}x")
    return rows
